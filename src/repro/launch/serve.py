"""Serving CLI: static batch driver + continuous-batching paged engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 4 --prompt-len 32 --gen 32 --quant vp

With --quant vp the weights are served as PACKED VP words (sign +
significand + exponent index in one int8/int16 per element,
`core.packing`), and every weight matmul routes through the Pallas
`vp_dequant_matmul` kernel — the packed words are consumed directly
in-tile, never materializing an f32 weight matrix in HBM.  This is the
paper's technique as a serving feature.

  --engine          serve via the continuous-batching PAGED engine
                    (`repro.serving`): fixed-size pages of packed VP
                    words + per-request block tables, FIFO admission
                    under the page budget, interleaved prefill/decode.
                    The static path (default) is retained as the parity
                    oracle — on the ref backend both emit bit-identical
                    tokens.
  --layout planes   legacy two-plane jnp-dequant serving (the golden
                    baseline the parity suite pins the kernel against)
  --kv-quant        additionally VP-quantizes the KV cache into PACKED
                    words consumed by the `vp_decode_attention` kernel
  --kv-layout planes  legacy two-plane KV cache (golden baseline)
  --tune-decode     run the M=1..B skinny-decode autotune profile over
                    the model's weight panels (and, with --kv-quant, the
                    decode-attention cache geometries) before serving
  --json F          write a serving report (tokens/sec, latency) to F
  --smoke           reduced config; also CHECKS finite logits end to end
                    (a real raise, not an assert — survives `python -O`)

All wall-clock numbers come from `time.perf_counter()` — never
`time.time()`, whose NTP steps skewed the committed tokens/sec reports —
and token sampling happens INSIDE the jitted decode step, so "decode
time" measures the model, not a host-side Python sampling loop.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import QuantConfig
from repro.models import (
    init_params, init_cache, prefill, decode_step, quantize_params,
)
from repro.models.layers import canonical_formats
from repro.serving.profile import quantized_bytes, tune_decode_profile


def _require_finite(logits, what: str) -> None:
    """Raise if any logit is NaN/inf.

    This is a runtime serving check on real model output, not an
    internal invariant — it must fire under `python -O` too, where
    `assert` statements are stripped, so it raises explicitly.
    """
    if not bool(jnp.isfinite(logits).all()):
        raise FloatingPointError(f"non-finite {what} logits")


def _percentile(xs, p: float) -> float:
    """Nearest-rank percentile of a small latency list."""
    ys = sorted(xs)
    i = min(len(ys) - 1, max(0, int(round(p / 100 * (len(ys) - 1)))))
    return ys[i]


def _ragged_gens(gen: int, n: int):
    """Deterministic ragged generation lengths in [gen/2, gen]."""
    span = max(1, gen // 2)
    return [max(1, gen - (i * 7) % (span + 1)) for i in range(n)]


def _run_engine(args, params, cfg, prompt_key, report):
    """Serve --batch requests through the paged continuous-batching
    engine (deterministic virtual clock charged with measured compute)."""
    from repro.serving import SLO_CLASSES, ServingEngine, VirtualClock

    n_req = args.batch
    gens = _ragged_gens(args.gen, n_req) if args.ragged_gen \
        else [args.gen] * n_req
    if args.arrival_gap > 0:
        arrivals = [i * args.arrival_gap for i in range(n_req)]
    else:
        arrivals = [0.0] * n_req
    ps = args.page_size
    capacity = -(-(args.prompt_len + max(gens)) // ps) * ps
    max_slots = args.max_slots or min(n_req, 4)
    engine = ServingEngine(
        params, cfg, max_slots=max_slots, capacity=capacity, page_size=ps,
        prefill_chunk=args.prefill_chunk, temperature=args.temperature,
        decode_lookahead=args.lookahead,
        clock=VirtualClock(), check_finite=args.smoke,
        hbm_budget_bytes=args.hbm_budget or None,
        policy=args.policy, preempt=args.preempt,
        max_queue=args.max_queue or None,
        on_nonfinite=args.on_nonfinite, degrade=args.degrade)
    slo = SLO_CLASSES[args.slo] if args.slo != "none" else None
    for i in range(n_req):
        prompt = jax.random.randint(
            jax.random.fold_in(prompt_key, i), (args.prompt_len,), 0,
            cfg.vocab)
        engine.submit(
            [int(t) for t in prompt], gens[i], arrivals[i],
            deadline=(arrivals[i] + args.deadline) if args.deadline else None,
            slo=slo)
    recs = engine.run()
    done = [r for r in recs if r["outcome"] in ("ok", "retried", "degraded")]
    total_tokens = sum(len(r["tokens"]) for r in done)
    ends = [r["finish_time"] for r in done if r["finish_time"] is not None]
    makespan = (max(ends) - min(r["arrival_time"] for r in recs)) \
        if ends else 0.0
    lats = [r["finish_time"] - r["arrival_time"] for r in done
            if r["finish_time"] is not None]
    tok_s = total_tokens / max(makespan, 1e-9)
    outcomes = {}
    for r in recs:
        outcomes[r["outcome"]] = outcomes.get(r["outcome"], 0) + 1
    report.update({
        "mode": "engine", "n_requests": n_req, "max_slots": max_slots,
        "page_size": ps, "capacity": capacity,
        "prefill_chunk": args.prefill_chunk,
        "decode_lookahead": args.lookahead,
        "hbm_cache_bytes": engine.kv.hbm_bytes(),
        "total_tokens": total_tokens, "makespan_s": makespan,
        "tokens_per_s": tok_s,
        "p50_latency_s": _percentile(lats, 50) if lats else None,
        "p99_latency_s": _percentile(lats, 99) if lats else None,
        "policy": args.policy, "outcomes": outcomes,
        "slo_met": sum(1 for r in recs if r.get("slo_met")),
        "stats": dict(engine.stats),
    })
    print(f"[engine] {n_req} requests x {max_slots} slots "
          f"(pages of {ps}): {total_tokens} tokens in {makespan:.2f}s "
          f"({tok_s:.1f} tok/s, p50 {report['p50_latency_s']}s, "
          f"p99 {report['p99_latency_s']}s)")
    if set(outcomes) - {"ok"}:
        print(f"[engine] outcomes: {outcomes}")
    print("[sample tokens]", [r["tokens"][:8] for r in recs[:4]])


def _run_static(args, params, cfg, prompt_key, sample_key, report):
    """The original fixed-batch driver: prefill once, decode N steps.
    Kept as the engine's parity oracle and padding-loss baseline."""
    B = args.batch
    prompts = jax.random.randint(
        prompt_key, (B, args.prompt_len), 0, cfg.vocab)
    caches = init_cache(cfg, B, args.prompt_len + args.gen)

    extra = None
    cross_kv = None
    if cfg.family == "vlm":
        extra = jnp.zeros((B, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        from repro.models.model import _encoder_forward, _cross_kv
        frames = jax.random.normal(
            jax.random.fold_in(prompt_key, 1),
            (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        enc = _encoder_forward(params, frames, cfg)
        cross_kv = _cross_kv(params, enc, cfg)
        extra = cross_kv

    t0 = time.perf_counter()
    logits, caches = prefill(params, prompts, caches, cfg, patches=extra)
    jax.block_until_ready(logits)
    prefill_s = time.perf_counter() - t0
    report["prefill_s"] = prefill_s
    print(f"[prefill] {B}x{args.prompt_len} in {prefill_s:.2f}s")
    if args.smoke:
        _require_finite(logits, f"prefill ({args.arch}, {args.quant})")

    temperature = args.temperature

    @jax.jit
    def decode(p, t, c, key):
        if cfg.family == "encdec":
            lg, c = decode_step(p, t, c, cfg, cross_kv=cross_kv)
        else:
            lg, c = decode_step(p, t, c, cfg)
        # Sampling INSIDE the jitted step: the decode timer must not
        # include a host round-trip + Python argmax per token.
        if temperature > 0:
            nxt = jax.random.categorical(key, lg / temperature)
        else:
            nxt = jnp.argmax(lg, -1)
        return nxt.astype(jnp.int32)[:, None], lg, c

    out_tokens = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    t0 = time.perf_counter()
    for i in range(args.gen):
        out_tokens.append(tok)
        tok, logits, caches = decode(
            params, tok, caches, jax.random.fold_in(sample_key, i))
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    if args.smoke:
        _require_finite(logits, f"decode ({args.arch}, {args.quant})")
    gen = jnp.concatenate(out_tokens, axis=1)
    tok_s = B * args.gen / dt
    report["decode_s"] = dt
    report["tokens_per_s"] = tok_s
    print(f"[decode] {args.gen} steps x batch {B}: {dt:.2f}s "
          f"({tok_s:.1f} tok/s)")
    print("[sample tokens]", np_preview(gen))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b",
                    choices=registry.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--quant", default="none",
                    choices=["none", "fxp", "vp", "vp_block"])
    ap.add_argument("--layout", default="packed",
                    choices=["packed", "planes"],
                    help="VP weight storage: packed kernel words (default)"
                         " or the legacy jnp-dequant two-plane baseline")
    ap.add_argument("--M", type=int, default=7,
                    help="VP significand bits; M+E <= 8 packs weights "
                         "into int8 words (half the bytes of bf16)")
    ap.add_argument("--E", type=int, default=2,
                    help="VP exponent-index bits (2^E exponent options)")
    ap.add_argument("--block", type=int, default=256,
                    help="vp_block index granularity; must divide the "
                         "contraction dims to engage the int8-MXU path "
                         "(non-tileable weights fall back to per-element "
                         "packed VP)")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--kv-layout", default="packed",
                    choices=["packed", "planes"],
                    help="VP KV-cache storage: packed kernel words "
                         "(default) or the legacy two-plane jnp-dequant "
                         "baseline")
    ap.add_argument("--tune-decode", action="store_true",
                    help="autotune the serving kernel at M=1..batch first")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="write a serving report (tokens/sec) to FILE")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    # Continuous-batching engine mode
    ap.add_argument("--engine", action="store_true",
                    help="serve --batch requests through the paged "
                         "continuous-batching engine instead of one "
                         "static batch")
    ap.add_argument("--max-slots", type=int, default=0,
                    help="concurrent requests resident in the paged "
                         "cache (default min(batch, 4))")
    ap.add_argument("--page-size", type=int, default=16,
                    help="cache positions per page")
    ap.add_argument("--lookahead", type=int, default=1,
                    help="fused decode run-ahead: decode this many "
                         "tokens per jitted dispatch (one gather + one "
                         "scatter amortized over the steps; tokens are "
                         "bit-identical to --lookahead 1)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="split prompt prefill into chunks of this many "
                         "tokens interleaved with decode steps "
                         "(full-causal models only)")
    ap.add_argument("--ragged-gen", action="store_true",
                    help="engine mode: vary per-request generation "
                         "lengths (deterministic ragged traffic)")
    ap.add_argument("--arrival-gap", type=float, default=0.0,
                    help="engine mode: stagger request arrivals by this "
                         "many virtual seconds")
    ap.add_argument("--hbm-budget", type=int, default=0,
                    help="engine mode: HBM byte budget sizing the page "
                         "pool (0 = fully committed)")
    # Resilience / scheduling (engine mode)
    ap.add_argument("--policy", default="fifo", choices=["fifo", "edf"],
                    help="engine admission: FIFO head-of-line or "
                         "earliest-deadline-first")
    ap.add_argument("--preempt", action="store_true",
                    help="EDF: allow preemption-by-eviction of later-"
                         "deadline running requests (re-admission "
                         "re-prefills, tokens are preserved)")
    ap.add_argument("--slo", default="none",
                    choices=["none", "interactive", "standard", "batch"],
                    help="attach this SLO class to every request "
                         "(derives per-request deadlines)")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="per-request completion deadline, seconds after "
                         "arrival (0 = none); expiry cancels with full "
                         "page reclamation")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded submit queue: arrivals beyond this "
                         "many waiting requests are shed (0 = unbounded)")
    ap.add_argument("--on-nonfinite", default="raise",
                    choices=["raise", "quarantine"],
                    help="smoke finite-check action: hard stop (default "
                         "for the CLI) or per-request quarantine")
    ap.add_argument("--degrade", action="store_true",
                    help="re-run repeatedly-quarantined requests on the "
                         "static golden-baseline path instead of "
                         "dropping them")
    args = ap.parse_args()

    quant = QuantConfig(mode=args.quant, M=args.M, E=args.E,
                        block=args.block,
                        quantize_kv_cache=args.kv_quant,
                        kv_layout=args.kv_layout)
    cfg = (registry.get_smoke_config(args.arch, quant) if args.smoke
           else registry.get_config(args.arch, quant))
    # Independent streams: model init, prompt draws, and sampling must
    # never share a key (weights correlated with benchmark activations).
    k_params, k_prompt, k_sample = jax.random.split(
        jax.random.PRNGKey(args.seed), 3)
    params = init_params(k_params, cfg)
    report = {"arch": args.arch, "quant": args.quant, "layout": args.layout,
              "kv_quant": bool(args.kv_quant), "kv_layout": args.kv_layout,
              "smoke": bool(args.smoke), "batch": args.batch,
              "prompt_len": args.prompt_len, "gen": args.gen}
    if args.kv_quant and args.kv_layout == "packed":
        from repro.models.attention import kv_cache_formats
        _, kv_vp = kv_cache_formats(cfg.quant)
        print(f"[serve] packed VP KV cache: {kv_vp.storage_bits} "
              f"bits/element ({kv_vp.M}+{kv_vp.E} info bits), "
              "kernel-backed decode attention")
    if args.quant != "none":
        params = quantize_params(params, cfg, layout=args.layout)
        qbytes = quantized_bytes(params)
        report["quantized_bytes"] = qbytes
        if args.quant == "vp" and args.layout == "packed":
            _, vp = canonical_formats(cfg.quant)
            print(f"[serve] packed VP words: {qbytes/1e6:.2f} MB "
                  f"({vp.storage_bits} bits/param, kernel-backed qdot)")
        else:
            print(f"[serve] quantized planes: {qbytes/1e6:.2f} MB")
    # Tunable decode surfaces: packed-word weight panels (vp + packed
    # layout) and/or the packed KV decode-attention cache — the latter is
    # independent of the weight quantization mode.
    tunable = (args.quant == "vp" and args.layout == "packed") or \
        (args.kv_quant and args.kv_layout == "packed")
    if args.tune_decode and tunable:
        t0 = time.perf_counter()
        prof = tune_decode_profile(
            params, cfg, args.batch,
            max_len=args.prompt_len + args.gen)
        if prof:
            n_entries = sum(
                len(v) if isinstance(v, dict) else 1
                for v in prof.values())
            print(f"[serve] decode autotune profile: "
                  f"{n_entries} entries over "
                  f"{len(prof)} shapes in {time.perf_counter()-t0:.1f}s")

    if args.engine:
        _run_engine(args, params, cfg, k_prompt, report)
    else:
        _run_static(args, params, cfg, k_prompt, k_sample, report)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
        print(f"[serve] wrote report to {args.json}")


def np_preview(x):
    import numpy as np
    a = np.asarray(x)
    return a[:, :12].tolist()


if __name__ == "__main__":
    main()
