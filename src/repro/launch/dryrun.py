"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k [--multi-pod] [--quant vp] [--out artifacts/...]

Proves the distribution config is coherent on the production meshes
(16x16 single pod; 2x16x16 multi-pod) without hardware: every input is a
ShapeDtypeStruct (no allocation), `.lower().compile()` must succeed, and
the compiled artifact yields the memory/cost/collective numbers consumed
by the roofline analysis (EXPERIMENTS.md).
"""
# The VERY FIRST lines — before ANY other import, since jax locks the
# device count on first init:
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
import re
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, QuantConfig
from repro.configs import registry
from repro.models import (
    init_params, init_cache, quantize_params, model_dtype,
)
from repro.optim.optimizer import OptConfig, init_opt_state
from repro.train.train_step import (
    make_train_step, make_serve_step, make_prefill_step,
)
from repro.parallel import sharding as shd
from repro.launch.mesh import make_production_mesh

from jax.sharding import NamedSharding, PartitionSpec as P

# Cells whose decode KV cache exceeds per-device HBM unless the cache
# SEQUENCE axis is sharded (flash-decode combine via GSPMD):
SEQ_SHARD_CACHE = {
    ("qwen3-0.6b", "decode_32k"): ("model",),
    ("stablelm-12b", "decode_32k"): ("model",),
    ("qwen3-moe-30b-a3b", "decode_32k"): ("model",),
    ("mixtral-8x22b", "decode_32k"): ("model",),
    ("zamba2-7b", "long_500k"): ("data", "model"),
    ("zamba2-7b", "decode_32k"): ("model",),
}
# Megatron-SP residual sharding for large train cells:
SEQ_SHARD_TRAIN = {
    "stablelm-12b", "gemma3-27b", "qwen3-moe-30b-a3b", "mixtral-8x22b",
}
# ZeRO-3 (weight FSDP) only where TP-sharded weights do not fit HBM;
# everything else keeps weights TP-only and shards ONLY the optimizer
# state over "data" (ZeRO-1) — full-weight all-gathers inside the layer
# scan cost 10-100x more collective volume than the ZeRO-1 grad
# reshard (Perf iteration 2 in EXPERIMENTS.md).
WEIGHT_FSDP_TRAIN = {"mixtral-8x22b"}


def _shape_struct(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree,
        is_leaf=lambda x: x is None)


def input_specs(arch: str, shape_name: str,
                quant: Optional[str] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = registry.get_config(arch)
    if quant and quant != "none":
        if quant == "kvq":
            qc = QuantConfig(mode="none", quantize_kv_cache=True)
        elif quant == "vp+kvq":
            qc = QuantConfig(mode="vp", quantize_kv_cache=True)
        else:
            qc = QuantConfig(mode=quant)
        cfg = dataclasses.replace(cfg, quant=qc)
    sh = registry.SHAPES[shape_name]
    S, GB, kind = sh["seq_len"], sh["global_batch"], sh["kind"]
    if kind == "train" and arch in SEQ_SHARD_TRAIN:
        cfg = dataclasses.replace(cfg, seq_shard=True)
    dt = model_dtype(cfg)
    d = cfg.d_model
    tok = jax.ShapeDtypeStruct((GB, S), jnp.int32)
    out: Dict[str, Any] = {"cfg": cfg, "kind": kind}

    if kind == "train":
        batch = {"tokens": tok, "labels": tok}
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (GB, cfg.encoder_seq, d), dt)
        if cfg.family == "vlm":
            batch["patches"] = jax.ShapeDtypeStruct(
                (GB, cfg.n_patches, d), dt)
        out["batch"] = batch
    elif kind == "prefill":
        out["tokens"] = tok
        out["caches"] = jax.eval_shape(
            lambda: init_cache(cfg, GB, S))
        if cfg.family == "encdec":
            KV, dh = cfg.n_kv_heads, cfg.head_dim
            out["extra"] = (
                jax.ShapeDtypeStruct(
                    (cfg.n_layers, GB, cfg.encoder_seq, KV, dh), dt),
                jax.ShapeDtypeStruct(
                    (cfg.n_layers, GB, cfg.encoder_seq, KV, dh), dt))
        elif cfg.family == "vlm":
            out["extra"] = jax.ShapeDtypeStruct((GB, cfg.n_patches, d), dt)
    else:  # decode
        out["token"] = jax.ShapeDtypeStruct((GB, 1), jnp.int32)
        out["caches"] = jax.eval_shape(
            lambda: init_cache(cfg, GB, S))
        if cfg.family == "encdec":
            KV, dh = cfg.n_kv_heads, cfg.head_dim
            out["cross_kv"] = (
                jax.ShapeDtypeStruct(
                    (cfg.n_layers, GB, cfg.encoder_seq, KV, dh), dt),
                jax.ShapeDtypeStruct(
                    (cfg.n_layers, GB, cfg.encoder_seq, KV, dh), dt))
    return out


def params_struct(cfg: ModelConfig, serving: bool):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    p = jax.eval_shape(lambda k: init_params(k, cfg), key)
    if serving and cfg.quant.mode != "none":
        p = jax.eval_shape(lambda q: quantize_params(q, cfg), p)
    return p


def replicated(tree, mesh):
    return jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, P(*([None] * x.ndim))), tree,
        is_leaf=lambda x: x is None)


def build_cell(arch: str, shape_name: str, mesh, quant: Optional[str] = None):
    """Returns (jitted_fn, arg_structs) ready to .lower()."""
    spec = input_specs(arch, shape_name, quant)
    cfg: ModelConfig = spec["cfg"]
    cfg = dataclasses.replace(
        cfg,
        mesh_batch_axes=shd.batch_axes(mesh),
        mesh_axis_sizes=tuple(dict(mesh.shape).items()))
    kind = spec["kind"]

    if kind == "train":
        pstruct = params_struct(cfg, serving=False)
        ostruct = jax.eval_shape(init_opt_state, pstruct)
        w_fsdp = arch in WEIGHT_FSDP_TRAIN
        psh = shd.param_shardings(pstruct, cfg, mesh, fsdp=w_fsdp)
        osh = type(ostruct)(
            step=NamedSharding(mesh, P()),
            mu=shd.param_shardings(ostruct.mu, cfg, mesh, fsdp=True),
            nu=shd.param_shardings(ostruct.nu, cfg, mesh, fsdp=True),
        )
        bsh = shd.batch_shardings(spec["batch"], mesh)
        fn = make_train_step(cfg, OptConfig())
        jfn = jax.jit(fn, in_shardings=(psh, osh, bsh))
        return jfn, (pstruct, ostruct, spec["batch"]), cfg

    serving = True
    pstruct = params_struct(cfg, serving=serving)
    # mixtral's TP-only weights (17.6 GB/dev) exceed HBM even at serve
    # time: keep 2D (data x model) weight sharding there (per-layer
    # gathers during decode — the price of a 280 GB model on 256 chips).
    psh = shd.param_shardings(pstruct, cfg, mesh,
                              fsdp=arch in WEIGHT_FSDP_TRAIN)
    seq_axes = SEQ_SHARD_CACHE.get((arch, shape_name))
    csh = shd.cache_shardings(spec["caches"], cfg, mesh, seq_axes=seq_axes)

    if kind == "prefill":
        fn = make_prefill_step(cfg)
        tsh = shd.batch_shardings(spec["tokens"], mesh)
        if "extra" in spec:
            esh = shd.batch_shardings(spec["extra"], mesh) \
                if cfg.family == "vlm" else replicated(spec["extra"], mesh)
            if cfg.family == "encdec":
                # cross K/V: (L, B, S_enc, KV, dh) -> batch on dim 1
                ax = shd.batch_axes(mesh)
                esh = jax.tree_util.tree_map(
                    lambda x: NamedSharding(
                        mesh, P(None, ax, None, None, None)), spec["extra"])
            jfn = jax.jit(fn, in_shardings=(psh, tsh, csh, esh))
            return jfn, (pstruct, spec["tokens"], spec["caches"],
                         spec["extra"]), cfg
        jfn = jax.jit(lambda p, t, c: fn(p, t, c),
                      in_shardings=(psh, tsh, csh))
        return jfn, (pstruct, spec["tokens"], spec["caches"]), cfg

    # decode
    fn = make_serve_step(cfg)
    tsh = shd.batch_shardings(spec["token"], mesh)
    if cfg.family == "encdec":
        ax = shd.batch_axes(mesh)
        xsh = jax.tree_util.tree_map(
            lambda x: NamedSharding(mesh, P(None, ax, None, None, None)),
            spec["cross_kv"])
        jfn = jax.jit(fn, in_shardings=(psh, tsh, csh, xsh))
        return jfn, (pstruct, spec["token"], spec["caches"],
                     spec["cross_kv"]), cfg
    jfn = jax.jit(fn, in_shardings=(psh, tsh, csh))
    return jfn, (pstruct, spec["token"], spec["caches"]), cfg


# ---------------------------------------------------------------------------
# Collective-bytes extraction from optimized HLO
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"^\s*(?:[%\w.\-]+)\s*=\s*([\w,\[\]{}() ]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum OUTPUT-shape bytes of every collective op, by op kind."""
    out: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes_str, kind = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shapes_str):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for v in dims.split(","):
                if v:
                    n *= int(v)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0.0) + nbytes
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             quant: Optional[str] = None,
             out_dir: str = "artifacts/dryrun") -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        jfn, args, cfg = build_cell(arch, shape_name, mesh, quant)
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "quant": quant or "none",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", -1.0)) if cost else -1.0,
        "bytes_accessed": float(cost.get("bytes accessed", -1.0))
        if cost else -1.0,
        "collective_bytes": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
            "output_bytes": getattr(mem, "output_size_in_bytes", -1),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", -1),
        },
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}_{shape_name}_{result['mesh']}" + (
        f"_{quant}" if quant else "")
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(result, f, indent=2)
    # Gzipped optimized HLO for the loop-aware roofline analyzer
    # (compiled.cost_analysis does NOT multiply while-loop bodies by their
    # trip counts, so benchmarks/hlo_cost.py re-derives FLOPs/bytes/
    # collective bytes from this text).
    import gzip
    with gzip.open(os.path.join(out_dir, tag + ".hlo.txt.gz"), "wt") as f:
        f.write(hlo)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=list(registry.ARCH_NAMES) + ["all"])
    ap.add_argument("--shape", default="all",
                    choices=list(registry.SHAPES) + ["all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--quant", default=None,
                    choices=[None, "none", "fxp", "vp", "vp_block", "kvq",
                             "vp+kvq"])
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    archs = registry.ARCH_NAMES if args.arch == "all" else [args.arch]
    ok, fail = 0, 0
    for arch in archs:
        shapes = (list(registry.SHAPES) if args.shape == "all"
                  else [args.shape])
        for shape in shapes:
            if (arch, shape) not in registry.cells() and \
                    shape == "long_500k":
                print(f"[skip] {arch} x {shape} (full attention @500k)")
                continue
            try:
                r = run_cell(arch, shape, args.multi_pod, args.quant,
                             args.out)
                print(f"[ok] {arch} x {shape} x {r['mesh']}: "
                      f"flops={r['flops']:.3e} "
                      f"coll={sum(r['collective_bytes'].values()):.3e}B "
                      f"compile={r['compile_s']}s")
                ok += 1
            except Exception as e:
                print(f"[FAIL] {arch} x {shape}: {type(e).__name__}: "
                      f"{str(e)[:500]}")
                fail += 1
    print(f"dryrun: {ok} ok, {fail} failed")
    sys.exit(1 if fail else 0)


if __name__ == "__main__":
    main()
