"""Production mesh factory.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
leading "pod" axis is pure data parallelism (DCN-connected pods).

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; `elastic_mesh` builds arbitrary healthy-subset
meshes for the fault-tolerance path, and `best_effort_mesh` factors
whatever device count the platform actually exposes (the sweep driver's
entry point under `--xla_force_host_platform_device_count`).
"""
from __future__ import annotations

import math

import jax

try:  # jax >= 0.4.35
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes have no axis types
    AxisType = None


def _mk(shape, axes):
    n_have = len(jax.devices())
    n_need = math.prod(shape)
    if n_need != n_have:
        raise ValueError(
            f"mesh {dict(zip(axes, shape))} needs {n_need} devices but "
            f"the platform exposes {n_have}; pick axis sizes whose "
            f"product is {n_have} (elastic_mesh / best_effort_mesh) or "
            f"launch with more devices "
            f"(--xla_force_host_platform_device_count on CPU)")
    if AxisType is not None and hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:n_need]).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def elastic_mesh(pods: int, data: int, model: int):
    """Mesh for an elastic restart on a reduced healthy set."""
    if min(pods, data, model) < 1:
        raise ValueError(
            f"mesh axis sizes must be >= 1, got pods={pods} data={data} "
            f"model={model}")
    if pods > 1:
        return _mk((pods, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))


def best_effort_mesh(n_devices=None, *, prefer: str = "model"):
    """("data", "model") mesh over the first `n_devices` available.

    Factors n into data x model, putting as much of it as possible on
    the preferred axis (all of it when n is prime).  The sweep driver
    uses this so one worker binary serves any
    --xla_force_host_platform_device_count.
    """
    if prefer not in ("data", "model"):
        raise ValueError(f"prefer must be 'data' or 'model': {prefer!r}")
    n_have = len(jax.devices())
    n = n_have if n_devices is None else int(n_devices)
    if not 1 <= n <= n_have:
        raise ValueError(
            f"best_effort_mesh(n_devices={n_devices}): platform exposes "
            f"{n_have} devices")
    shape = (1, n) if prefer == "model" else (n, 1)
    devs = jax.devices()[:n]
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs).reshape(shape), ("data", "model"))


def smoke_mesh():
    """1-device mesh with production axis names (CPU tests)."""
    return _mk((1, 1), ("data", "model"))
