"""Production mesh factory.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
leading "pod" axis is pure data parallelism (DCN-connected pods).

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; `elastic_mesh` builds arbitrary healthy-subset
meshes for the fault-tolerance path.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def _mk(shape, axes):
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def elastic_mesh(pods: int, data: int, model: int):
    """Mesh for an elastic restart on a reduced healthy set."""
    if pods > 1:
        return _mk((pods, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))


def smoke_mesh():
    """1-device mesh with production axis names (CPU tests)."""
    return _mk((1, 1), ("data", "model"))
